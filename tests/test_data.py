"""Data pipeline: sources, packing, prefetch, straggler re-dispatch."""
import time

import numpy as np

from repro.data import (Prefetcher, SyntheticText, lm_batches,
                        register_tokenizer_image)


def test_lm_batches_shapes_and_shift():
    src = SyntheticText(100, doc_len=64, seed=0)
    it = lm_batches(src, batch=3, seq=16, vocab_size=100)
    b = next(it)
    assert b["tokens"].shape == (3, 16)
    assert b["labels"].shape == (3, 16)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_source_determinism():
    a = next(iter(SyntheticText(50, doc_len=32, seed=7)))
    b = next(iter(SyntheticText(50, doc_len=32, seed=7)))
    np.testing.assert_array_equal(a, b)


def test_prefetcher_produces():
    src = SyntheticText(100, doc_len=64, seed=0)
    pf = Prefetcher(lambda: lm_batches(src, 2, 8, 100), capacity=2)
    batches = [next(pf) for _ in range(5)]
    assert len(batches) == 5
    assert pf.stats["produced"] >= 5
    pf.close()


def test_prefetcher_straggler_respawn():
    """A slow batch triggers speculative re-dispatch (stats counted)."""
    def make_iter():
        def gen():
            i = 0
            while True:
                if i == 2:
                    time.sleep(0.4)       # straggler
                yield {"i": np.asarray([i])}
                i += 1
        return gen()

    pf = Prefetcher(make_iter, capacity=2, deadline_s=0.1)
    got = [int(next(pf)["i"][0]) for _ in range(5)]
    assert got == [0, 1, 2, 3, 4]          # order + exactly-once output
    assert pf.stats["respawned"] >= 1
    pf.close()


def test_tokenizer_image_registered():
    register_tokenizer_image()
    from repro.core import MaRe
    raw = np.arange(40, dtype=np.int32)
    out = MaRe((raw,)).map(image="tools/tokenizer",
                           vocab_size=17).collect()
    assert out[0].shape == (40,)
    assert out[0].max() < 17
