"""Check intra-repo markdown links in README.md and docs/*.md.

Every relative link target (``[text](path)`` and ``[text](path#anchor)``)
must exist on disk, resolved against the file containing the link;
``#anchor``-only links are checked against the same file's headings
(GitHub slug rules: lowercase, spaces to dashes, punctuation dropped).
External links (http/https/mailto) are not fetched — CI must not depend
on the network. Exit code 1 lists every broken link.

  python tools/check_links.py [repo_root]
"""
from __future__ import annotations

import glob
import os
import re
import sys

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
CODE_FENCE_RE = re.compile(r"^```.*?^```", re.MULTILINE | re.DOTALL)


def slugify(heading: str) -> str:
    """GitHub's anchor slug: strip markup, lowercase, spaces -> dashes."""
    text = re.sub(r"[`*_\[\]()]", "", heading).strip().lower()
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def check_file(path: str) -> list:
    with open(path) as f:
        raw = f.read()
    body = CODE_FENCE_RE.sub("", raw)          # links in code blocks are text
    anchors = {slugify(h) for h in HEADING_RE.findall(body)}
    errors = []
    for target in LINK_RE.findall(body):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        dest, _, fragment = target.partition("#")
        if not dest:                           # same-file #anchor
            if fragment and slugify(fragment) not in anchors:
                errors.append(f"{path}: broken anchor '#{fragment}'")
            continue
        resolved = os.path.normpath(os.path.join(os.path.dirname(path), dest))
        if not os.path.exists(resolved):
            errors.append(f"{path}: broken link '{target}' "
                          f"(resolved: {resolved})")
    return errors


def main() -> int:
    root = sys.argv[1] if len(sys.argv) > 1 else "."
    files = sorted(glob.glob(os.path.join(root, "README.md"))
                   + glob.glob(os.path.join(root, "docs", "*.md")))
    if not files:
        print("check_links: no markdown files found", file=sys.stderr)
        return 1
    errors = [e for path in files for e in check_file(path)]
    for err in errors:
        print(err, file=sys.stderr)
    print(f"check_links: {len(files)} files, "
          f"{len(errors)} broken links")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
